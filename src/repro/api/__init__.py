"""repro.api — hoist-once analysis sessions.

The paper's core lesson is "read the big matrix once, fuse everything
else". The subsystems below it (``core.operators``, ``stats.engine``)
apply that *within* one analysis; this package applies it *across* a whole
session: a microbiome study runs PCoA, PERMANOVA, PERMDISP, ANOSIM and
Mantel back-to-back on the **same** distance matrix (Sfiligoi et al. 2021),
and every shared O(n²) hoist — Gower centering, the operator's row/global
means, the rank transform, the ordination coordinates — should be computed
once and reused, not re-derived per entry point.

* ``Workspace(dm, config=ExecConfig(...))`` — validates and canonicalizes
  the matrix once, then serves every analysis off a lazy ``HoistCache``.
  ``Workspace.from_features(table, metric=...)`` opens the session one
  step upstream: the ``repro.dist`` driver produces condensed distances
  tile-by-tile with the operator means fused into the sweep, so the
  matrix-free analyses never allocate an n×n square.
* ``ExecConfig``   — the single home for execution knobs that used to be
  scattered per-function kwargs.
* ``OrdinationResult`` / ``PermutationTestResult`` — the two unified
  result shapes, with the RNG key recorded.

Legacy free functions (``core.pcoa.pcoa``, ``stats.permanova``, ...) keep
their signatures and are thin wrappers over a one-shot Workspace — same
p-values per key, none of the cross-analysis reuse.

``config``/``results`` import nothing from ``repro`` (so core/stats can
import them cycle-free); ``Workspace`` loads lazily for the same reason.
"""

from repro.api.config import ExecConfig
from repro.api.results import OrdinationResult

__all__ = ["ExecConfig", "OrdinationResult", "PermutationTestResult",
           "HoistCache", "Workspace"]

_LAZY = ("Workspace", "HoistCache", "PermutationTestResult")


def __getattr__(name):
    # PEP 562 lazy loading: workspace pulls in core+stats, which themselves
    # import api.config/api.results during *their* init — resolving these
    # names on first use (instead of at package import) breaks the cycle.
    if name in ("Workspace", "HoistCache"):
        from repro.api import workspace
        return getattr(workspace, name)
    if name == "PermutationTestResult":
        from repro.stats.engine import PermutationTestResult
        return PermutationTestResult
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
