"""Workspace: hoist-once analysis sessions over one distance matrix.

The paper optimizes each analysis in isolation — validate in one pass,
center in two, hoist the permutation-invariants out of the Monte-Carlo
loop. But a real study (Sfiligoi et al. 2021, "Enabling microbiome
research on personal devices") runs *several* analyses on the **same**
matrix back-to-back, and the free-function API made each one re-pay the
O(n²) reads: ``pcoa`` and ``permdisp`` each re-hoisted the operator means,
``permanova`` re-centered, ``anosim`` re-ranked, every ``mantel`` call
re-normalized both matrices.

``Workspace`` is the session object that finishes the argument:

* construction validates (fused single-pass) and canonicalizes the matrix
  **once** — fp32 storage, optional device placement — exactly like the
  paper's §4.3 validation caching, extended to every derived artifact;
* the shared hoists live behind a lazy ``HoistCache`` keyed by artifact —
  row/global means of E = −½D∘D (``operator``), the materialized Gower
  matrix (``gram``), the rank transform (``ranks``), condensed
  normalization moments (``moments``) and their square hat form
  (``hat_full``), and full PCoA solutions (``coords``) — each computed on
  first use and reused by every later analysis in the session;
* every analysis method threads the session's single ``ExecConfig``
  through ``core.pcoa``, ``stats.engine`` and the kernel dispatchers, and
  returns the unified ``OrdinationResult`` / ``PermutationTestResult``
  with the resolved RNG key recorded.

The legacy free functions (``core.pcoa.pcoa``, ``core.mantel.mantel``,
``stats.permanova`` …) are thin wrappers over a one-shot Workspace — same
signatures, identical p-values per key — so the only thing a session
changes is how often D is read.
"""

from __future__ import annotations

from collections import Counter
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExecConfig
from repro.api.results import OrdinationResult
from repro.core.distance_matrix import DistanceMatrix
from repro.core.mantel import MantelStatistic, condensed_moments, hat_square
from repro.core.operators import CenteredGramOperator
from repro.core.pcoa import pcoa as _pcoa
from repro.core.pcoa import resolve_dimensions
from repro.stats import engine
from repro.stats.anosim import AnosimStatistic, rank_transform
from repro.stats.engine import PermutationTestResult, as_key
from repro.stats.partial_mantel import (PartialMantelPallasStatistic,
                                        PartialMantelStatistic)
from repro.stats.permanova import PermanovaStatistic
from repro.stats.permdisp import PermdispStatistic


class HoistCache:
    """Keyed store for a session's shared hoisted artifacts, instrumented
    with per-key hit/miss counters so "the O(n²) hoist ran exactly once"
    is a testable property, not a hope.

    Keys are either artifact names ("operator", "gram", "ranks",
    "moments", "hat_full") or tuples whose first element is the artifact
    name (("coords", k, method, key-fingerprint)). ``misses[key]`` counts
    builds, ``hits[key]`` counts reuses.
    """

    def __init__(self):
        self._store = {}
        self.hits = Counter()
        self.misses = Counter()

    def get(self, key, build):
        """The cached value for ``key``, building (and counting a miss) on
        first use."""
        if key in self._store:
            self.hits[key] += 1
        else:
            self.misses[key] += 1
            self._store[key] = build()
        return self._store[key]

    def counts(self, key) -> tuple:
        """(hits, misses) for one key."""
        return self.hits[key], self.misses[key]

    def build_count(self, artifact: str) -> int:
        """Total builds of an artifact family (e.g. every ("coords", ...)
        entry counts toward "coords")."""
        return sum(c for k, c in self.misses.items()
                   if (k if isinstance(k, str) else k[0]) == artifact)

    def keys(self):
        return self._store.keys()

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)


def _key_fingerprint(key) -> tuple:
    """Hashable identity of a PRNG key, for cache keys."""
    try:
        data = jax.random.key_data(key)
    except Exception:                    # raw uint32 key array
        data = key
    return tuple(int(v) for v in np.asarray(data).ravel())


class Workspace:
    """One distance matrix + one ExecConfig + a HoistCache = a session.

    ``dm`` may be a validated ``DistanceMatrix`` (trusted, per the paper's
    §4.3 validation caching) or a raw square array (validated here, once,
    via the fused single-pass check). The matrix is canonicalized to fp32
    and optionally pinned to ``config.device``; every analysis method then
    serves off the shared cache. See the module docstring for the artifact
    inventory.
    """

    def __init__(self, dm: Union[DistanceMatrix, jax.Array, np.ndarray],
                 config: Optional[ExecConfig] = None, validate: bool = True):
        self.config = config if config is not None else ExecConfig()
        if not isinstance(dm, DistanceMatrix):
            dm = DistanceMatrix(jnp.asarray(dm), validate=validate)
        elif validate and not dm._validated:
            # a DistanceMatrix built with validate=False is NOT trusted
            # just for its wrapper type — the session's validate flag
            # decides, exactly as for a raw array
            dm = DistanceMatrix(dm.data, ids=dm.ids, validate=True)
        data = dm.data
        if data.dtype != jnp.float32:
            data = data.astype(jnp.float32)
        if self.config.device is not None:
            data = jax.device_put(data, self.config.device)
        if data is dm.data and dm._validated:
            self._dm = dm
        else:
            # the session matrix is trusted once admitted — whether by the
            # validation pass above, by the source DistanceMatrix's own
            # cached validation, or by an explicit validate=False opt-out —
            # so downstream copies (e.g. inside pcoa) never revalidate
            self._dm = DistanceMatrix(data, ids=dm.ids,
                                      _skip_validation=True)
        self.n = len(self._dm)
        self.cache = HoistCache()

    # -- canonical views ----------------------------------------------------
    @property
    def dm(self) -> DistanceMatrix:
        return self._dm

    @property
    def data(self) -> jax.Array:
        return self._dm.data

    # -- shared hoisted artifacts -------------------------------------------
    def operator(self) -> CenteredGramOperator:
        """The matrix-free centered-Gram operator: row/global means of
        E = −½D∘D hoisted in ONE read of D."""
        return self.cache.get("operator", lambda: (
            CenteredGramOperator.from_distance(
                self.data, block=self.config.block,
                impl=self.config.matvec_impl,
                interpret=self.config.interpret)))

    def gram(self) -> jax.Array:
        """The materialized Gower-centered matrix (PERMANOVA's hoist; the
        eigh / materialized-ordination paths), via config.centering_impl."""
        from repro.core.pcoa import materialized_gram
        return self.cache.get("gram", lambda: materialized_gram(
            self.data, self.config.centering_impl, self.config.mesh))

    def ranks(self) -> dict:
        """ANOSIM's rank transform: the O(m log m) sort, run once."""
        return self.cache.get("ranks",
                              lambda: rank_transform(self.data, self.n))

    def moments(self) -> dict:
        """Condensed normalization moments (centered norm + the
        centered-normalized vector, O(m)) — the shared currency of the
        Mantel family's x-side."""
        return self.cache.get("moments",
                              lambda: condensed_moments(self.data, self.n))

    def hat_full(self) -> jax.Array:
        """Square symmetric centered-normalized form (diag 0) — the
        Mantel family's y-side hoist, O(n²), built only when this matrix
        is actually used as a fixed side."""
        return self.cache.get("hat_full",
                              lambda: hat_square(self.moments(), self.n))

    # -- analyses -----------------------------------------------------------
    def pcoa(self, dimensions: int = 10, method: str = "fsvd",
             key=None) -> OrdinationResult:
        """Principal Coordinates Analysis off the cached operator/gram.

        Full ``OrdinationResult`` objects are cached per
        (dimensions, method, key), so ``ws.permdisp`` reuses the exact
        coordinates a previous ``ws.pcoa`` produced.
        """
        k = resolve_dimensions(dimensions, self.n)
        key = as_key(key, default=42)
        fp = _key_fingerprint(key) if method == "fsvd" else None
        cache_key = ("coords", k, method, fp)

        def build():
            kw = {}
            if method == "eigh" or (method == "fsvd"
                                    and self.config.materialize):
                kw["gram"] = self.gram()
            else:
                # matrix-free paths — including the distributed matvec,
                # whose exact trace() comes off the same hoisted means
                kw["operator"] = self.operator()
            return _pcoa(self._dm, dimensions=k, method=method, key=key,
                         config=self.config, **kw)

        return self.cache.get(cache_key, build)

    def permanova(self, grouping, permutations: int = 999, key=None,
                  batch_size: Optional[int] = None) -> PermutationTestResult:
        """PERMANOVA off the cached Gower centering (one-sided, greater)."""
        codes, num_groups = self._codes(grouping)
        stat = PermanovaStatistic(self.data, codes, self.n, num_groups,
                                  pre={"g": self.gram()})
        return engine.permutation_test(
            stat, permutations, key, alternative="greater",
            batch_size=self.config.resolve_batch_size(batch_size, 32),
            config=self.config, method="permanova")

    def anosim(self, grouping, permutations: int = 999, key=None,
               batch_size: Optional[int] = None) -> PermutationTestResult:
        """ANOSIM off the cached rank transform (one-sided, greater)."""
        codes, num_groups = self._codes(grouping)
        stat = AnosimStatistic(self.data, codes, self.n, num_groups,
                               pre=self.ranks())
        return engine.permutation_test(
            stat, permutations, key, alternative="greater",
            batch_size=self.config.resolve_batch_size(batch_size, 32),
            config=self.config, method="anosim")

    def permdisp(self, grouping, permutations: int = 999, key=None,
                 dimensions: Optional[int] = None, method: str = "fsvd",
                 batch_size: Optional[int] = None) -> PermutationTestResult:
        """PERMDISP off the cached ordination (one-sided, greater).

        The coordinate hoist is shared with ``ws.pcoa`` at matching
        (dimensions, method) — the whole ordination is computed at most
        once per session."""
        codes, num_groups = self._codes(grouping)
        dims = resolve_dimensions(dimensions, self.n)
        coords = self.pcoa(dimensions=dims, method=method).coordinates
        stat = PermdispStatistic(coords, codes, self.n, num_groups)
        return engine.permutation_test(
            stat, permutations, key, alternative="greater",
            batch_size=self.config.resolve_batch_size(batch_size, 32),
            config=self.config, method="permdisp")

    def mantel(self, other, permutations: int = 999, key=None,
               alternative: str = "two-sided",
               batch_size: Optional[int] = None) -> PermutationTestResult:
        """Mantel test of this matrix (permuted side) against ``other``
        (a Workspace, DistanceMatrix or raw array; held fixed). Both
        sides' normalization hoists come from their sessions' caches."""
        other = self._coerce(other)
        if other.n != self.n:
            raise ValueError("x and y must have the same shape")
        pre = {"normxm": self.moments()["norm"],
               "y_full": other.hat_full()}
        stat = MantelStatistic(self.data, other.data, self.n, pre=pre)
        return engine.permutation_test(
            stat, permutations, key, alternative=alternative,
            batch_size=self.config.resolve_batch_size(batch_size, 8),
            config=self.config, method="mantel")

    def partial_mantel(self, other, control, permutations: int = 999,
                       key=None, alternative: str = "two-sided",
                       batch_size: Optional[int] = None
                       ) -> PermutationTestResult:
        """Partial Mantel of this matrix against ``other``, controlling
        for ``control``; ŷ is residualized from cached moments. Routes
        through the Pallas reduction when ``config.kernel == "pallas"``."""
        y, z = self._coerce(other), self._coerce(control)
        if not (self.n == y.n == z.n):
            raise ValueError("x, y and z must have the same shape")
        ym, zm = y.moments(), z.moments()
        r_yz = jnp.dot(ym["hat"], zm["hat"])
        # eager degeneracy check (can't raise inside the jitted engine):
        # |r_yz|→1 makes the residualization 0/0, NaN-ing the whole null.
        # 1e-5, not 1e-6: an fp32 self-correlation rounds to 1-r² as large
        # as ~1e-6, and any genuine r_yz this close is numerically useless
        r = float(r_yz)
        if 1.0 - r * r < 1e-5:
            raise ValueError(
                f"y and z are (nearly) collinear (r_yz={r:.6f}); the "
                f"partial correlation is undefined — use the plain Mantel "
                f"test")
        denom = jnp.sqrt(1.0 - r_yz * r_yz)
        z_full = z.hat_full()
        pre = {"normxm": self.moments()["norm"], "r_yz": r_yz,
               "y_res_full": (y.hat_full() - r_yz * z_full) / denom,
               "z_full": z_full}
        if self.config.kernel == "pallas":
            stat = PartialMantelPallasStatistic(
                self.data, y.data, z.data, self.n, pre=pre,
                block=self.config.block, interpret=self.config.interpret)
        else:
            stat = PartialMantelStatistic(self.data, y.data, z.data,
                                          self.n, pre=pre)
        return engine.permutation_test(
            stat, permutations, key, alternative=alternative,
            batch_size=self.config.resolve_batch_size(batch_size, 8),
            config=self.config, method="partial_mantel")

    # -- plumbing -----------------------------------------------------------
    def _codes(self, grouping):
        codes, num_groups = engine.encode_grouping(grouping)
        if codes.size != self.n:
            raise ValueError("grouping length does not match distance "
                             "matrix")
        return jnp.asarray(codes), num_groups

    def _coerce(self, other) -> "Workspace":
        """Other operands join the session: an existing Workspace keeps its
        own cache; anything else gets a one-shot Workspace on this
        session's config. A DistanceMatrix's validation status is trusted
        as constructed (paper §4.3 — exactly what the pre-session free
        functions did); raw arrays are validated on admission."""
        if isinstance(other, Workspace):
            return other
        return Workspace(other, config=self.config,
                         validate=not isinstance(other, DistanceMatrix))

    def __repr__(self):
        return (f"Workspace(n={self.n}, cached={sorted(map(str, self.cache.keys()))}, "
                f"config={self.config})")
