"""Workspace: hoist-once analysis sessions over one distance matrix.

The paper optimizes each analysis in isolation — validate in one pass,
center in two, hoist the permutation-invariants out of the Monte-Carlo
loop. But a real study (Sfiligoi et al. 2021, "Enabling microbiome
research on personal devices") runs *several* analyses on the **same**
matrix back-to-back, and the free-function API made each one re-pay the
O(n²) reads: ``pcoa`` and ``permdisp`` each re-hoisted the operator means,
``permanova`` re-centered, ``anosim`` re-ranked, every ``mantel`` call
re-normalized both matrices.

``Workspace`` is the session object that finishes the argument:

* construction validates (fused single-pass) and canonicalizes the matrix
  **once** — fp32 storage, optional device placement — exactly like the
  paper's §4.3 validation caching, extended to every derived artifact;
* the shared hoists live behind a lazy ``HoistCache`` keyed by artifact —
  row/global means of E = −½D∘D (``operator``), the materialized Gower
  matrix (``gram``), the condensed distances (``condensed``), the
  condensed rank transform (``ranks``), condensed normalization moments
  (``moments``), and full PCoA solutions (``coords``) — each computed on
  first use and reused by every later analysis in the session;
* every analysis method threads the session's single ``ExecConfig``
  through ``core.pcoa``, ``stats.engine`` and the kernel dispatchers, and
  returns the unified ``OrdinationResult`` / ``PermutationTestResult``
  with the resolved RNG key recorded.

The legacy free functions (``core.pcoa.pcoa``, ``core.mantel.mantel``,
``stats.permanova`` …) are thin wrappers over a one-shot Workspace — same
signatures, identical p-values per key — so the only thing a session
changes is how often D is read.

``Workspace.from_features`` extends the session one step upstream: the
distance matrix itself is produced by the tiled ``repro.dist`` driver in
CONDENSED layout, with the operator means and Mantel moments accumulated
during the same sweep — and since the Mantel family and ANOSIM now run
their permutation loops over condensed storage too
(``kernels.permute_reduce`` closed-form triangle gathers), a
feature-backed session completes the ENTIRE analysis battery — PCoA,
PERMANOVA, PERMDISP, ANOSIM, Mantel, partial Mantel — with no n×n
matrix of any kind ever allocated. The only remaining square builds are
explicit opt-ins: ``gram`` for eigh/materialized ordination, and the
``"square"`` key when the caller demands ``ws.dm`` itself. ``refresh()``
invalidates the whole cache (generation-counted) when the underlying
data changes.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import ExecConfig
from repro.api.results import OrdinationResult
from repro.core.distance_matrix import DistanceMatrix, condensed_to_square
from repro.core.mantel import MantelStatistic, condensed_moments_vec
from repro.core.operators import (CenteredGramOperator,
                                  CondensedCenteredGramOperator)
from repro.core.pcoa import pcoa as _pcoa
from repro.core.pcoa import resolve_dimensions
from repro.core.validation import ensure_finite
from repro.dist import get_metric, pairwise_condensed
from repro.obs.ledger import FEATURE_HOIST_PASSES, HOIST_PASSES
from repro.obs.report import ObsSession, RunReport, build_report
from repro.obs.trace import NULL_OBS
from repro.stats import engine
from repro.stats.anosim import AnosimStatistic, rank_transform_condensed
from repro.stats.engine import PermutationTestResult, as_key
from repro.stats.partial_mantel import (PartialMantelPallasStatistic,
                                        PartialMantelStatistic)
from repro.stats.permanova import (PermanovaOperatorStatistic,
                                   PermanovaStatistic)
from repro.stats.permdisp import PermdispStatistic


class HoistCache:
    """Keyed store for a session's shared hoisted artifacts, instrumented
    with per-key hit/miss counters so "the O(n²) hoist ran exactly once"
    is a testable property, not a hope.

    Keys are either artifact names ("operator", "gram", "condensed",
    "ranks", "moments") or tuples whose first element is the artifact
    name (("coords", k, method, key-fingerprint)). ``misses[key]`` counts
    builds, ``hits[key]`` counts reuses.

    When a Workspace binds its ``ObsSession`` (``bind_obs``), every miss
    additionally runs under a ``hoist:<artifact>`` span and charges the
    session's analytic traffic ledger from the audited pass registry
    (``obs.ledger.HOIST_PASSES`` / ``FEATURE_HOIST_PASSES`` — the same
    table ``benchmarks/bench_api.py`` accounts with, so a ``RunReport``'s
    hoist totals reproduce the BENCH_api numbers live). Unbound caches
    talk to the no-op singleton: zero overhead, identical counters.
    """

    def __init__(self):
        self._store = {}
        self.hits = Counter()
        self.misses = Counter()
        self.obs = NULL_OBS
        self.n = 0
        self.pass_table = None

    def bind_obs(self, obs, n: int, table=None) -> "HoistCache":
        """Attach the observing session + the pass-table column (square-
        vs feature-backed) that prices this cache's builds."""
        self.obs = obs
        self.n = n
        self.pass_table = table
        return self

    def get(self, key, build):
        """The cached value for ``key``, building (and counting a miss) on
        first use."""
        if key in self._store:
            self.hits[key] += 1
        else:
            self.misses[key] += 1
            art = key if isinstance(key, str) else key[0]
            with self.obs.span(f"hoist:{art}", phase="hoist",
                               key=str(key), n=self.n):
                self._store[key] = build()
            self.obs.charge_hoist(art, self.n, table=self.pass_table)
        return self._store[key]

    def counts(self, key) -> tuple:
        """(hits, misses) for one key."""
        return self.hits[key], self.misses[key]

    def build_count(self, artifact: str) -> int:
        """Total builds of an artifact family (e.g. every ("coords", ...)
        entry counts toward "coords")."""
        return sum(c for k, c in self.misses.items()
                   if (k if isinstance(k, str) else k[0]) == artifact)

    def keys(self):
        return self._store.keys()

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)

    # -- resident-set accounting -------------------------------------------
    def nbytes(self, key=None) -> int:
        """Resident bytes of one cached artifact, or of the whole cache.

        This is the currency of ``repro.serve``'s byte-budgeted session
        eviction: a pooled study's cost is exactly its HoistCache's
        resident set. With ``key=None`` the total deduplicates shared
        buffers (e.g. the operator holds a reference to the same
        condensed array the ``"condensed"`` entry stores — it is counted
        once); a per-key query counts that artifact's full reachable set.
        Unknown keys cost 0.
        """
        if key is not None:
            if key not in self._store:
                return 0
            return _resident_nbytes(self._store[key], set())
        return sum(self.nbytes_by_key().values())

    def nbytes_by_key(self) -> dict:
        """``{key: resident bytes}`` with shared buffers charged to the
        FIRST key (insertion order) that reaches them — so the values sum
        to the deduplicated total ``nbytes()`` returns."""
        seen: set = set()
        return {k: _resident_nbytes(v, seen)
                for k, v in self._store.items()}


def _resident_nbytes(value, seen: set) -> int:
    """Bytes of every array buffer reachable from ``value``, walking
    dicts/sequences/dataclasses (``OrdinationResult`` is a plain frozen
    dataclass, not a pytree, so ``tree_leaves`` would treat it as one
    opaque leaf — field recursion sees through it, and through the
    operator dataclasses alike). ``seen`` dedups by object identity
    across calls that share it."""
    if value is None or isinstance(value, (bool, int, float, complex, str,
                                           bytes)):
        return 0
    if id(value) in seen:
        return 0
    seen.add(id(value))
    nb = getattr(value, "nbytes", None)
    if isinstance(nb, (int, np.integer)):
        return int(nb)
    if isinstance(value, dict):
        return sum(_resident_nbytes(v, seen) for v in value.values())
    if isinstance(value, (list, tuple)):
        return sum(_resident_nbytes(v, seen) for v in value)
    if dataclasses.is_dataclass(value):
        return sum(_resident_nbytes(getattr(value, f.name), seen)
                   for f in dataclasses.fields(value))
    return 0


@jax.jit
def _centered_normalized(flat, mean, norm):
    """One fused O(m) pass: the hat vector from the production's fused
    mean/norm scalars."""
    return (flat - mean) / norm


def _key_fingerprint(key) -> tuple:
    """Hashable identity of a PRNG key, for cache keys."""
    try:
        data = jax.random.key_data(key)
    except Exception:                    # raw uint32 key array
        data = key
    return tuple(int(v) for v in np.asarray(data).ravel())


class Workspace:
    """One distance matrix + one ExecConfig + a HoistCache = a session.

    ``dm`` may be a validated ``DistanceMatrix`` (trusted, per the paper's
    §4.3 validation caching) or a raw square array (validated here, once,
    via the fused single-pass check). The matrix is canonicalized to fp32
    and optionally pinned to ``config.device``; every analysis method then
    serves off the shared cache. See the module docstring for the artifact
    inventory.
    """

    def __init__(self,
                 dm: Union[DistanceMatrix, jax.Array, np.ndarray, None] = None,
                 config: Optional[ExecConfig] = None, validate: bool = True,
                 *, features=None, metric=None):
        self.config = config if config is not None else ExecConfig()
        # the as-requested config survives resolution so refresh() (a new
        # n) re-solves from the user's intent, not a previous solution
        self.config_requested = self.config
        self.tuned = None
        self.generation = 0
        self.cache = HoistCache()
        # the observability session rides the whole Workspace lifetime
        # (spans accumulate across refresh() generations; each report
        # records the generation it snapshot). Disabled -> the shared
        # no-op singleton: every span/charge is a constant-time no-op.
        self._obs = (ObsSession(self.config.obs)
                     if self.config.obs.enabled else NULL_OBS)
        if features is not None:
            if dm is not None:
                raise ValueError("pass a distance matrix OR a feature "
                                 "table, not both")
            self._admit_features(features, metric)
        else:
            if dm is None:
                raise ValueError("Workspace needs a distance matrix (or "
                                 "features= — see Workspace.from_features)")
            self._admit_dm(dm, validate)
        self._resolve_config()
        self._bind_cache()

    @classmethod
    def from_features(cls, features, metric=None,
                      config: Optional[ExecConfig] = None) -> "Workspace":
        """A session straight from an (n, d) feature table — the fused
        ``repro.dist`` path.

        The distances are produced tile-by-tile in CONDENSED layout on
        first use, and the operator means (and the Mantel-side condensed
        moments) are accumulated during that same sweep — so the whole
        analysis battery (``pcoa(method="fsvd")``, ``permanova``,
        ``permdisp``, ``anosim``, ``mantel``, ``partial_mantel``) runs
        without an n×n matrix of any kind ever existing: the permutation
        loops gather condensed storage by closed-form triangle indexing.
        The only square builds left are explicit opt-ins (``gram`` for
        eigh/materialized ordination; the lazily-counted ``"square"``
        key when ``ws.dm`` itself is demanded).

        ``metric`` is a ``repro.dist`` name or ``Metric`` instance
        (default: ``config.metric``, Bray–Curtis). The table is validated
        finite on admission (shared ``ensure_finite`` path) and
        canonicalized to fp32 like a distance matrix would be.
        """
        return cls(features=features, metric=metric, config=config)

    # -- admission (shared by __init__ and refresh) -------------------------
    def _admit_dm(self, dm, validate: bool) -> None:
        if not isinstance(dm, DistanceMatrix):
            arr = jnp.asarray(dm)
            # finite first: a NaN would otherwise surface as a baffling
            # "matrix is not symmetric" (NaN != NaN) — or, with
            # validate=False, propagate silently into eigenvalues
            ensure_finite(arr)
            dm = DistanceMatrix(arr, validate=validate)
        else:
            ensure_finite(dm.data)
            if validate and not dm._validated:
                # a DistanceMatrix built with validate=False is NOT trusted
                # just for its wrapper type — the session's validate flag
                # decides, exactly as for a raw array
                dm = DistanceMatrix(dm.data, ids=dm.ids, validate=True)
        data = dm.data
        if data.dtype != jnp.float32:
            data = data.astype(jnp.float32)
        if self.config.device is not None:
            data = jax.device_put(data, self.config.device)
        if data is dm.data and dm._validated:
            self._dm = dm
        else:
            # the session matrix is trusted once admitted — whether by the
            # validation pass above, by the source DistanceMatrix's own
            # cached validation, or by an explicit validate=False opt-out —
            # so downstream copies (e.g. inside pcoa) never revalidate
            self._dm = DistanceMatrix(data, ids=dm.ids,
                                      _skip_validation=True)
        self._features = None
        self._metric = None
        self.n = len(self._dm)

    def _admit_features(self, features, metric) -> None:
        x = jnp.asarray(features)
        if x.ndim != 2:
            raise ValueError(f"expected an (n, d) feature table, "
                             f"got shape {x.shape}")
        ensure_finite(x, what="feature table")
        if x.dtype != jnp.float32:
            x = x.astype(jnp.float32)
        if self.config.device is not None:
            x = jax.device_put(x, self.config.device)
        self._features = x
        self._metric = get_metric(metric if metric is not None
                                  else self.config.metric)
        self._dm = None
        self.n = int(x.shape[0])

    # -- cache lifecycle ----------------------------------------------------
    def refresh(self, dm=None, *, features=None, metric=None) -> "Workspace":
        """Invalidate every cached hoist and bump ``generation``.

        The HoistCache assumes the session matrix never changes under it;
        when it does — the caller mutated their source buffer, or wants to
        re-point the session at a new matrix/table — ``refresh`` is the
        documented way back to a consistent state: all cached artifacts
        (operator means, gram, ranks, coords, condensed, ...) are dropped
        with fresh hit/miss counters, and the next analysis re-runs each
        hoist exactly once. Pass ``dm=`` or ``features=`` to re-admit new
        data (same validation/canonicalization as construction); with no
        arguments the current matrix/table is kept and only the caches
        drop. Returns ``self`` for chaining.
        """
        if dm is not None and features is not None:
            raise ValueError("pass a distance matrix OR a feature table, "
                             "not both")
        self.generation += 1
        self.cache = HoistCache()
        if dm is not None:
            self._admit_dm(dm, validate=True)
        elif features is not None:
            self._admit_features(features,
                                 metric if metric is not None
                                 else self._metric)
        elif self._features is not None:
            # feature-backed: the lazily-materialized square (if any) was
            # derived from the dropped production — it goes too
            self._dm = None
        self._resolve_config()
        self._bind_cache()
        return self

    def _resolve_config(self) -> None:
        """Materialize the requested config's auto knobs against the
        admitted data's (n, d) via ``repro.tune`` — ``self.config`` is
        always concrete after admission; ``self.config_requested`` keeps
        the user's intent and ``self.tuned`` the solver record (None
        when nothing asked for tuning)."""
        d = (int(self._features.shape[1]) if self._features is not None
             else None)
        self.config, self.tuned = self.config_requested.resolve(self.n, d)

    def _bind_cache(self) -> None:
        """Point the (fresh) HoistCache at the session's observability
        state and the pass-table column matching the current backing."""
        self.cache.bind_obs(
            self._obs, self.n,
            FEATURE_HOIST_PASSES if self._features is not None
            else HOIST_PASSES)

    # -- observability -------------------------------------------------------
    @property
    def obs(self):
        """The session's ``ObsSession`` (or the shared no-op singleton
        when ``config.obs.enabled`` is False)."""
        return self._obs

    def resolved_tiles(self) -> dict:
        """The tile geometry this session EXECUTES — post-tune (the
        solver's choices when auto) and post-snap (the shared
        ``kernels.dispatch`` lane rule at this backend/problem size) —
        as opposed to the requested knob values ``config`` carries.
        ``report()`` embeds this, so a RunReport records what actually
        ran."""
        from repro.kernels.dispatch import (lane_geometry, pick_block,
                                            snap_chunk)
        from repro.kernels.permute_reduce_ops import DEFAULT_CHUNK
        lane, floor = lane_geometry(self.config.interpret)
        m = self.n * (self.n - 1) // 2
        chunk = (self.config.chunk if self.config.chunk is not None
                 else DEFAULT_CHUNK)
        tiles = {
            "block": self.config.block,
            "block_executed": pick_block(self.n, self.config.block, lane,
                                         floor=floor),
            "feature_block": self.config.feature_block,
            "feature_block_executed": (
                max(min(self.config.feature_block,
                        int(self._features.shape[1])), 1)
                if self._features is not None
                else self.config.feature_block),
            "batch_size": self.config.resolve_batch_size(None, 32),
            "chunk": chunk,
            "chunk_executed": snap_chunk(m, chunk)[0],
            "lane": lane,
            "auto": self.tuned is not None,
        }
        return tiles

    def report(self, meta: Optional[dict] = None) -> RunReport:
        """The session's ``RunReport``: span tree, analytic ledger
        totals, HoistCache hit/miss counters, the recompile sentinel's
        trace/program deltas for this session's window, and the
        resolved tile geometry (plus the full ``repro.tune`` record —
        chosen tiles, modeled bytes, budget — when the config was
        auto-solved). With observability disabled the report still
        carries the always-on telemetry (cache counters + the
        sentinel's process snapshot) with empty spans and ledger."""
        by_key = self.cache.nbytes_by_key()
        base = {"n": self.n, "generation": self.generation,
                "backing": ("features" if self._features is not None
                            else "distance_matrix"),
                "obs_enabled": self._obs.enabled,
                "tiles": self.resolved_tiles(),
                "cache_nbytes": {"total": sum(by_key.values()),
                                 "by_key": {str(k): v
                                            for k, v in by_key.items()}}}
        if self.tuned is not None:
            base["tune"] = self.tuned.to_dict()
        if meta:
            base.update(meta)
        measured = drift = None
        if self._obs.enabled and self.config.obs.probe:
            from repro.obs.drift import DriftSentinel
            from repro.obs.probe import probe_session
            measured = probe_session(self)
            drift = DriftSentinel().reconcile(measured)
        return build_report(self._obs if self._obs.enabled else None,
                            cache=self.cache, meta=base,
                            measured=measured, drift=drift)

    # -- canonical views ----------------------------------------------------
    @property
    def dm(self) -> DistanceMatrix:
        """The session's square DistanceMatrix. For a feature-backed
        session this MATERIALIZES the n×n square from the condensed
        production on first access (cache key ``"square"``) — no
        analysis method demands it anymore; it exists for callers who
        want the matrix itself (export, plotting, the distributed
        column-sharded paths)."""
        if self._dm is None:
            square = self.cache.get("square", lambda: condensed_to_square(
                self.condensed(), self.n))
            self._dm = DistanceMatrix(square, _skip_validation=True)
        return self._dm

    @property
    def data(self) -> jax.Array:
        return self.dm.data

    # -- shared hoisted artifacts -------------------------------------------
    def _produce_distances(self) -> None:
        """Run the tiled ``repro.dist`` production (feature-backed sessions
        only): ONE sweep over the feature table builds BOTH cache entries —
        ``"condensed"`` (the pdist-layout distances) and ``"dist_means"``
        (the operator row/global means + the Mantel moments, accumulated
        while each tile was resident). The two keys miss together, by
        construction."""
        if "condensed" in self.cache and "dist_means" in self.cache:
            return
        with self._obs.span("ws.produce_distances", phase="production",
                            n=self.n, d=int(self._features.shape[1]),
                            metric=self._metric.name,
                            impl=self.config.pairwise_impl):
            prod = pairwise_condensed(
                self._features, self._metric, block=self.config.block,
                feature_block=self.config.feature_block,
                impl=self.config.pairwise_impl,
                interpret=self.config.interpret)
        self.cache.get("condensed", lambda: prod["condensed"])
        self.cache.get("dist_means", lambda: {
            k: prod[k] for k in ("row_means", "global_mean", "mean",
                                 "norm")})

    def condensed(self) -> jax.Array:
        """The condensed (scipy ``pdist`` layout) distances. Feature-backed
        sessions produce them tile-by-tile (never a square); square-backed
        sessions extract the upper triangle once."""
        if self._features is not None:
            self._produce_distances()
            return self.cache.get("condensed", lambda: None)
        return self.cache.get("condensed",
                              lambda: self._dm.condensed_form())

    def operator(self):
        """The matrix-free centered-Gram operator: row/global means of
        E = −½D∘D hoisted in ONE read of D — or, for a feature-backed
        session, taken for FREE from the production sweep's fused
        accumulators and served over the condensed storage."""
        if self._features is not None:
            def build():
                self._produce_distances()
                means = self.cache.get("dist_means", lambda: None)
                return CondensedCenteredGramOperator(
                    self.cache.get("condensed", lambda: None),
                    means["row_means"], means["global_mean"], self.n,
                    self.config.block)
            return self.cache.get("operator", build)
        return self.cache.get("operator", lambda: (
            CenteredGramOperator.from_distance(
                self.data, block=self.config.block,
                impl=self.config.matvec_impl,
                interpret=self.config.interpret)))

    def gram(self) -> jax.Array:
        """The materialized Gower-centered matrix (PERMANOVA's hoist; the
        eigh / materialized-ordination paths), via config.centering_impl."""
        from repro.core.pcoa import materialized_gram
        return self.cache.get("gram", lambda: materialized_gram(
            self.data, self.config.centering_impl, self.config.mesh))

    def ranks(self) -> dict:
        """ANOSIM's rank transform: the O(m log m) sort, run once — and
        kept CONDENSED: the batched permutation loop gathers the
        condensed within-indicator, so no square rank matrix exists
        anywhere. Both backings rank the shared ``"condensed"`` artifact
        (for a square-backed session that is one cached triangle
        extraction, also reused by ``moments``)."""
        return self.cache.get("ranks", lambda: rank_transform_condensed(
            self.condensed()))

    def moments(self) -> dict:
        """Condensed normalization moments (centered norm + the
        centered-normalized vector, O(m)) — the shared currency of BOTH
        Mantel-family sides: the permuted side consumes ``norm``, a fixed
        side contributes its ``hat`` vector directly (condensed — since
        the batched loop gathers condensed storage, no square hat form
        exists anymore). Feature-backed sessions CONSUME the production
        sweep's fused mean/norm scalars (accumulated while the tiles were
        resident — no extra reduction passes; the Σd²−m·mean² form
        differs from ``condensed_moments`` at ~1e-4 relative, which the
        Mantel statistics absorb: observed and null draws share the
        scale) and only pay the one O(m) center-and-divide for the hat
        vector itself."""
        if self._features is not None:
            def build():
                self._produce_distances()
                means = self.cache.get("dist_means", lambda: None)
                return {"norm": means["norm"],
                        "hat": _centered_normalized(
                            self.cache.get("condensed", lambda: None),
                            means["mean"], means["norm"])}
            return self.cache.get("moments", build)
        return self.cache.get("moments", lambda: condensed_moments_vec(
            self.condensed()))

    # -- analyses -----------------------------------------------------------
    def pcoa(self, dimensions: int = 10, method: str = "fsvd",
             key=None) -> OrdinationResult:
        """Principal Coordinates Analysis off the cached operator/gram.

        Full ``OrdinationResult`` objects are cached per
        (dimensions, method, key), so ``ws.permdisp`` reuses the exact
        coordinates a previous ``ws.pcoa`` produced. An ``eigh`` request
        for k dimensions is additionally served by SLICING any cached
        higher-k eigh solution (the exact solver computes the full
        spectrum and keeps the top k, so the slice is bitwise what a
        direct solve would return) — counted as a hit on the higher-k
        entry, no re-solve. (fsvd can't be sliced: its sketch width is
        k-dependent.)
        """
        k = resolve_dimensions(dimensions, self.n)
        key = as_key(key, default=42)
        fp = _key_fingerprint(key) if method == "fsvd" else None
        cache_key = ("coords", k, method, fp)

        def build():
            if method == "eigh" or (method == "fsvd"
                                    and self.config.materialize):
                return _pcoa(self.dm, dimensions=k, method=method, key=key,
                             config=self.config, check_finite=False,
                             gram=self.gram())
            # matrix-free paths — including the distributed matvec, whose
            # exact trace() comes off the same hoisted means. A feature-
            # backed session passes dm=None: fully matrix-free off the
            # condensed operator (the distributed matvec still needs the
            # square, so it goes through self.dm).
            dm = self.dm if self.config.centering_impl == "distributed" \
                else self._dm
            return _pcoa(dm, dimensions=k, method=method, key=key,
                         config=self.config, check_finite=False,
                         operator=self.operator())

        if method == "eigh" and cache_key not in self.cache:
            cands = [kk for kk in self.cache.keys()
                     if isinstance(kk, tuple) and kk[0] == "coords"
                     and kk[2] == "eigh" and kk[1] >= k]
            if cands:
                src = min(cands, key=lambda kk: kk[1])
                full = self.cache.get(src, lambda: None)  # reuse: a hit

                def build():    # noqa: F811 — slice, don't re-solve
                    return OrdinationResult(
                        coordinates=full.coordinates[:, :k],
                        eigenvalues=full.eigenvalues[:k],
                        proportion_explained=full.proportion_explained[:k],
                        method="eigh", key=None)

        with self._obs.span("ws.pcoa", n=self.n, dimensions=k,
                            method=method):
            return self.cache.get(cache_key, build)

    # -- statistic construction (the serve seam) -----------------------------
    def statistic(self, method: str, *, grouping=None, other=None,
                  control=None, dimensions: Optional[int] = None,
                  pcoa_method: str = "fsvd"):
        """Build the hoisted ``(statistic, default_alternative)`` pair for
        one permutation test, without running the Monte-Carlo loop.

        This is the seam the analysis methods below and the
        ``repro.serve`` scheduler share: the statistic carries every
        cached hoist (so constructing it triggers at most the session's
        one-time artifact builds), and the caller decides how to drive
        the loop — ``engine.permutation_test`` for a whole test here,
        ``engine.hoist_and_observe`` + ``engine.tile_statistics`` for the
        front door's coalesced tiles. ``default_alternative`` is the
        test's canonical sidedness ("greater" for the grouping tests,
        "two-sided" for the Mantel family).
        """
        if method == "permanova":
            # a feature-backed session runs the OPERATOR form: the
            # per-permutation quadratic forms stream op.matvec(Z_p) off
            # the condensed storage, so neither the square D nor the
            # square Gower matrix is ever materialized
            # (config.materialize=True restores the materialized baseline)
            codes, num_groups = self._codes(grouping)
            if self._features is not None and not self.config.materialize:
                return PermanovaOperatorStatistic(
                    self.operator(), codes, self.n, num_groups), "greater"
            return PermanovaStatistic(self.data, codes, self.n, num_groups,
                                      pre={"g": self.gram()}), "greater"
        if method == "anosim":
            # ranks stay condensed end to end; the statistic's dm field is
            # only consumed when no pre-hoisted ranks are supplied
            codes, num_groups = self._codes(grouping)
            return AnosimStatistic(None, codes, self.n, num_groups,
                                   pre=self.ranks(),
                                   kernel=self.config.kernel,
                                   interpret=self.config.interpret,
                                   chunk=self.config.chunk), "greater"
        if method == "permdisp":
            codes, num_groups = self._codes(grouping)
            dims = resolve_dimensions(dimensions, self.n)
            coords = self.pcoa(dimensions=dims,
                               method=pcoa_method).coordinates
            return PermdispStatistic(coords, codes, self.n,
                                     num_groups), "greater"
        if method == "mantel":
            y = self._coerce(other)
            if y.n != self.n:
                raise ValueError("x and y must have the same shape")
            pre = {"normxm": self.moments()["norm"],
                   "ynorm": y.moments()["hat"]}
            return MantelStatistic(self.condensed(), None, self.n, pre=pre,
                                   kernel=self.config.kernel,
                                   interpret=self.config.interpret,
                                   chunk=self.config.chunk), "two-sided"
        if method == "partial_mantel":
            y, z = self._coerce(other), self._coerce(control)
            if not (self.n == y.n == z.n):
                raise ValueError("x, y and z must have the same shape")
            ym, zm = y.moments(), z.moments()
            r_yz = jnp.dot(ym["hat"], zm["hat"])
            # eager degeneracy check (can't raise inside the jitted
            # engine): |r_yz|→1 makes the residualization 0/0, NaN-ing
            # the whole null. 1e-5, not 1e-6: an fp32 self-correlation
            # rounds to 1-r² as large as ~1e-6, and any genuine r_yz this
            # close is numerically useless
            r = float(r_yz)
            if 1.0 - r * r < 1e-5:
                raise ValueError(
                    f"y and z are (nearly) collinear (r_yz={r:.6f}); the "
                    f"partial correlation is undefined — use the plain "
                    f"Mantel test")
            denom = jnp.sqrt(1.0 - r_yz * r_yz)
            pre = {"normxm": self.moments()["norm"], "r_yz": r_yz,
                   "y_res": (ym["hat"] - r_yz * zm["hat"]) / denom,
                   "z": zm["hat"]}
            # fixed sides ride in via pre only (their y/z fields are
            # consumed solely by the no-pre hoist) — nothing square for
            # any operand
            cls = (PartialMantelPallasStatistic
                   if self.config.kernel == "pallas"
                   else PartialMantelStatistic)
            return cls(self.condensed(), None, None, self.n, pre=pre,
                       kernel=self.config.kernel,
                       interpret=self.config.interpret,
                       chunk=self.config.chunk), "two-sided"
        raise ValueError(
            f"unknown method {method!r}; expected one of ('permanova', "
            f"'anosim', 'permdisp', 'mantel', 'partial_mantel')")

    def permanova(self, grouping, permutations: int = 999, key=None,
                  batch_size: Optional[int] = None) -> PermutationTestResult:
        """PERMANOVA off the cached Gower centering (one-sided, greater).

        A feature-backed session runs the OPERATOR form instead: the
        per-permutation quadratic forms stream ``op.matvec(Z_p)`` off the
        condensed storage, so neither the square D nor the square Gower
        matrix is ever materialized (``config.materialize=True`` restores
        the materialized-gram baseline)."""
        with self._obs.span("ws.permanova", n=self.n,
                            permutations=permutations):
            stat, alt = self.statistic("permanova", grouping=grouping)
            return engine.permutation_test(
                stat, permutations, key, alternative=alt,
                batch_size=self.config.resolve_batch_size(batch_size, 32),
                config=self.config, method="permanova")

    def anosim(self, grouping, permutations: int = 999, key=None,
               batch_size: Optional[int] = None) -> PermutationTestResult:
        """ANOSIM off the cached rank transform (one-sided, greater).

        The ranks stay condensed end to end: the batched loop gathers
        the condensed within-indicator by closed-form triangle indexing,
        so neither backing ever materializes a square rank matrix."""
        with self._obs.span("ws.anosim", n=self.n,
                            permutations=permutations,
                            kernel=self.config.kernel):
            stat, alt = self.statistic("anosim", grouping=grouping)
            return engine.permutation_test(
                stat, permutations, key, alternative=alt,
                batch_size=self.config.resolve_batch_size(batch_size, 32),
                config=self.config, method="anosim")

    def permdisp(self, grouping, permutations: int = 999, key=None,
                 dimensions: Optional[int] = None, method: str = "fsvd",
                 batch_size: Optional[int] = None) -> PermutationTestResult:
        """PERMDISP off the cached ordination (one-sided, greater).

        The coordinate hoist is shared with ``ws.pcoa`` at matching
        (dimensions, method) — the whole ordination is computed at most
        once per session."""
        dims = resolve_dimensions(dimensions, self.n)
        with self._obs.span("ws.permdisp", n=self.n,
                            permutations=permutations, dimensions=dims):
            stat, alt = self.statistic("permdisp", grouping=grouping,
                                       dimensions=dims, pcoa_method=method)
            return engine.permutation_test(
                stat, permutations, key, alternative=alt,
                batch_size=self.config.resolve_batch_size(batch_size, 32),
                config=self.config, method="permdisp")

    def mantel(self, other, permutations: int = 999, key=None,
               alternative: str = "two-sided",
               batch_size: Optional[int] = None) -> PermutationTestResult:
        """Mantel test of this matrix (permuted side) against ``other``
        (a Workspace, DistanceMatrix or raw array; held fixed). Fully
        square-free: the permuted side rides in as the shared condensed
        artifact (the batched loop's closed-form triangle gathers replace
        the n×n ``x[order][:, order]`` buffer), the fixed side
        contributes only its CONDENSED hat vector — neither session ever
        demands the lazy ``"square"`` key, so feature-backed Workspaces
        run the whole Mantel family with no n×n distance matrix."""
        with self._obs.span("ws.mantel", n=self.n,
                            permutations=permutations,
                            kernel=self.config.kernel):
            stat, _ = self.statistic("mantel", other=other)
            return engine.permutation_test(
                stat, permutations, key, alternative=alternative,
                batch_size=self.config.resolve_batch_size(batch_size, 32),
                config=self.config, method="mantel")

    def partial_mantel(self, other, control, permutations: int = 999,
                       key=None, alternative: str = "two-sided",
                       batch_size: Optional[int] = None
                       ) -> PermutationTestResult:
        """Partial Mantel of this matrix against ``other``, controlling
        for ``control``; ŷ is residualized from cached moments — all
        three operands stay condensed (square-free like ``mantel``).
        Routes through the Pallas ``permute_reduce`` backend when
        ``config.kernel == "pallas"``."""
        with self._obs.span("ws.partial_mantel", n=self.n,
                            permutations=permutations,
                            kernel=self.config.kernel):
            stat, _ = self.statistic("partial_mantel", other=other,
                                     control=control)
            return engine.permutation_test(
                stat, permutations, key, alternative=alternative,
                batch_size=self.config.resolve_batch_size(batch_size, 32),
                config=self.config, method="partial_mantel")

    # -- plumbing -----------------------------------------------------------
    def _codes(self, grouping):
        codes, num_groups = engine.encode_grouping(grouping)
        if codes.size != self.n:
            raise ValueError("grouping length does not match distance "
                             "matrix")
        return jnp.asarray(codes), num_groups

    def _coerce(self, other) -> "Workspace":
        """Other operands join the session: an existing Workspace keeps its
        own cache; anything else gets a one-shot Workspace on this
        session's config. A DistanceMatrix's validation status is trusted
        as constructed (paper §4.3 — exactly what the pre-session free
        functions did); raw arrays are validated on admission."""
        if isinstance(other, Workspace):
            return other
        return Workspace(other, config=self.config,
                         validate=not isinstance(other, DistanceMatrix))

    def __repr__(self):
        return (f"Workspace(n={self.n}, cached={sorted(map(str, self.cache.keys()))}, "
                f"config={self.config})")
