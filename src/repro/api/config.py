"""ExecConfig: one home for every execution knob in the analysis stack.

Before the `repro.api` redesign the knobs that decide *how* an analysis
executes — which matvec kernel, which centering implementation, whether to
materialize the Gower matrix, Pallas tile sizes, the permutation batch,
the device mesh — were scattered as inconsistent per-function kwargs
(`pcoa(matvec_impl=..., block=...)`, `partial_mantel(kernel=...)`,
`permutation_test(batch_size=...)`, ...). ``ExecConfig`` collects them in
a single frozen pytree dataclass that threads uniformly through
``api.Workspace``, ``core.pcoa``, ``core.mantel``, ``stats.engine`` and
the kernel dispatchers.

It is registered as a *leaf-free* pytree (every field is static metadata),
so it can sit inside jitted pytrees or static args: two configs compare
equal iff every knob matches, and each distinct config keys its own jit
cache entry.

This module deliberately imports nothing from ``repro`` except
``repro.obs.config`` (itself import-free) so any layer — core, stats,
kernels — can import it without cycles.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax

from repro.obs.config import ObsConfig


# mirror of repro.dist.METRICS — kept literal here because this module
# imports nothing from repro (pinned in sync by tests/test_dist.py)
_KNOWN_METRICS = ("braycurtis", "canberra", "cityblock", "euclidean",
                  "jaccard")


@partial(jax.tree_util.register_dataclass,
         data_fields=[],
         meta_fields=["matvec_impl", "centering_impl", "materialize",
                      "interpret", "block", "batch_size", "kernel", "mesh",
                      "device", "metric", "pairwise_impl", "feature_block",
                      "obs"])
@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution configuration shared by every analysis entry point.

    Fields
    ------
    matvec_impl:
        Backend for ``CenteredGramOperator.matvec`` — ``"xla"`` (row-blocked
        jnp matmuls, the default) or ``"pallas"`` (the VMEM-tiled
        ``kernels.center_matvec`` kernel).
    centering_impl:
        Implementation used whenever a *materialized* Gower-centered matrix
        is required (PERMANOVA's hoist, ``pcoa(method="eigh")``, the
        ``materialize=True`` fallback) — ``"ref"`` (eager multi-pass
        oracle), ``"fused"`` (single-jit two-pass, the default) or
        ``"distributed"`` (shard_map over ``mesh``).
    materialize:
        ``True`` restores the legacy materialize-then-solve ordination path
        (the benchmark baseline); ``False`` (default) runs PCoA matrix-free
        through the operator.
    interpret:
        Pallas dispatch mode — ``None`` (default) auto-resolves per backend
        (native on TPU, interpreter elsewhere, e.g. this container's CPU);
        ``True``/``False`` force it.
    block:
        Row/column tile size for the operator matvec and the Pallas kernels
        (lane-snapped per backend by ``kernels.center_matvec_ops.pick_block``).
    batch_size:
        Permutations evaluated per engine tile — for the batch-fused
        statistics (Mantel family, ANOSIM) this is exactly the B grid
        dimension of ``kernels.permute_reduce``: each hoisted condensed
        invariant streams ONCE per tile and is reused by all B
        permutations, so bigger batches mean less traffic per
        permutation (peak memory is one (B, chunk) gather tile). ``None``
        (default) keeps each test's tuned default (32 everywhere since
        the condensed loop; the engine pads partial tiles so any K
        compiles exactly one program).
    kernel:
        Backend for the batched condensed permutation reductions of the
        Mantel family and ANOSIM — ``"xla"`` (default; the ``lax.scan``
        twin of the kernel) or ``"pallas"`` (``kernels.permute_reduce``
        with explicit VMEM chunk streaming).
    mesh:
        Optional ``jax.sharding.Mesh`` for the distributed paths
        (``centering_impl="distributed"``, distributed matvec/engine).
    device:
        Optional ``jax.Device`` the Workspace pins its canonical matrix to
        (``None``: wherever jax placed it).
    metric:
        Default beta-diversity metric for feature-table sessions
        (``Workspace.from_features`` with ``metric=None``) — any
        ``repro.dist`` registry name ("braycurtis", "euclidean",
        "jaccard", "canberra", "cityblock").
    pairwise_impl:
        Backend for the ``repro.dist`` tiled distance production —
        ``"xla"`` (the ``lax.map`` row-panel fallback, the default) or
        ``"pallas"`` (the VMEM-tiled ``kernels.pairwise`` kernel).
    feature_block:
        Feature-axis chunk of the pairwise metric reduce: bounds the
        per-tile broadcast term at (rows, cols, feature_block).
    obs:
        Observability switchboard (``repro.obs.ObsConfig``). The default
        (``enabled=False``) is the zero-overhead contract: no session is
        created, every span/charge resolves to the shared no-op
        singletons. ``ObsConfig(enabled=True)`` makes the Workspace own
        an ``ObsSession`` — span tracer + analytic traffic ledger +
        recompile-sentinel window — readable via ``Workspace.report()``.
        ``None`` coerces to the disabled default.
    """

    matvec_impl: str = "xla"
    centering_impl: str = "fused"
    materialize: bool = False
    interpret: Optional[bool] = None
    block: int = 256
    batch_size: Optional[int] = None
    kernel: str = "xla"
    mesh: Optional[Any] = None
    device: Optional[Any] = None
    metric: str = "braycurtis"
    pairwise_impl: str = "xla"
    feature_block: int = 128
    obs: Optional[ObsConfig] = ObsConfig()

    def __post_init__(self):
        if self.obs is None:
            object.__setattr__(self, "obs", ObsConfig())
        if not isinstance(self.obs, ObsConfig):
            raise ValueError(f"obs must be an ObsConfig (or None), "
                             f"got {self.obs!r}")
        if self.matvec_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown matvec_impl {self.matvec_impl!r}")
        if self.centering_impl not in ("ref", "fused", "distributed"):
            raise ValueError(f"unknown centering_impl "
                             f"{self.centering_impl!r}")
        if self.kernel not in ("xla", "pallas"):
            raise ValueError(f"unknown kernel {self.kernel!r}")
        if self.centering_impl == "distributed" and self.mesh is None:
            raise ValueError("centering_impl='distributed' requires a mesh")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")
        if self.batch_size is not None and self.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1 or None, "
                             f"got {self.batch_size}")
        if self.metric not in _KNOWN_METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; "
                             f"available: {list(_KNOWN_METRICS)}")
        if self.pairwise_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown pairwise_impl "
                             f"{self.pairwise_impl!r}")
        if self.feature_block < 1:
            raise ValueError(f"feature_block must be >= 1, "
                             f"got {self.feature_block}")

    def replace(self, **changes) -> "ExecConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)

    def resolve_batch_size(self, explicit: Optional[int],
                           default: int) -> int:
        """Precedence: explicit call-site arg > config > per-test default."""
        if explicit is not None:
            return explicit
        if self.batch_size is not None:
            return self.batch_size
        return default
