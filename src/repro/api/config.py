"""ExecConfig: one home for every execution knob in the analysis stack.

Before the `repro.api` redesign the knobs that decide *how* an analysis
executes — which matvec kernel, which centering implementation, whether to
materialize the Gower matrix, Pallas tile sizes, the permutation batch,
the device mesh — were scattered as inconsistent per-function kwargs
(`pcoa(matvec_impl=..., block=...)`, `partial_mantel(kernel=...)`,
`permutation_test(batch_size=...)`, ...). ``ExecConfig`` collects them in
a single frozen pytree dataclass that threads uniformly through
``api.Workspace``, ``core.pcoa``, ``core.mantel``, ``stats.engine`` and
the kernel dispatchers.

It is registered as a *leaf-free* pytree (every field is static metadata),
so it can sit inside jitted pytrees or static args: two configs compare
equal iff every knob matches, and each distinct config keys its own jit
cache entry.

This module deliberately imports nothing from ``repro`` except
``repro.obs.config`` (itself import-free) so any layer — core, stats,
kernels — can import it without cycles.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Union

import jax

from repro.obs.config import ObsConfig


# mirror of repro.dist.METRICS — kept literal here because this module
# imports nothing from repro (pinned in sync by tests/test_dist.py)
_KNOWN_METRICS = ("braycurtis", "canberra", "cityblock", "euclidean",
                  "jaccard")


@partial(jax.tree_util.register_dataclass,
         data_fields=[],
         meta_fields=["matvec_impl", "centering_impl", "materialize",
                      "interpret", "block", "batch_size", "kernel", "mesh",
                      "device", "metric", "pairwise_impl", "feature_block",
                      "chunk", "auto", "tune_profile", "obs"])
@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution configuration shared by every analysis entry point.

    Fields
    ------
    matvec_impl:
        Backend for ``CenteredGramOperator.matvec`` — ``"xla"`` (row-blocked
        jnp matmuls, the default) or ``"pallas"`` (the VMEM-tiled
        ``kernels.center_matvec`` kernel).
    centering_impl:
        Implementation used whenever a *materialized* Gower-centered matrix
        is required (PERMANOVA's hoist, ``pcoa(method="eigh")``, the
        ``materialize=True`` fallback) — ``"ref"`` (eager multi-pass
        oracle), ``"fused"`` (single-jit two-pass, the default) or
        ``"distributed"`` (shard_map over ``mesh``).
    materialize:
        ``True`` restores the legacy materialize-then-solve ordination path
        (the benchmark baseline); ``False`` (default) runs PCoA matrix-free
        through the operator.
    interpret:
        Pallas dispatch mode — ``None`` (default) auto-resolves per backend
        (native on TPU, interpreter elsewhere, e.g. this container's CPU);
        ``True``/``False`` force it.
    block:
        Row/column tile size for the operator matvec and the Pallas kernels
        (lane-snapped per backend by the shared ``kernels.dispatch``
        policy). ``"auto"``: solved by ``repro.tune`` as the largest
        lane-snapped block whose modeled resident set (one D tile + the
        x panels; plus the production strip when feature-backed) fits
        the backend budget, *capped at the default* (shrink-only, like
        feature_block): distance production is bitwise-invariant in
        block (each produced element reduces the full feature axis
        regardless of row-panel membership), but the operator matvec
        re-associates panel partial sums, so auto keeps the default
        geometry whenever it fits — bitwise-identical results — and
        shrinks only under budget pressure, where matvec-backed
        ordination/PERMANOVA agree to fp tolerance instead.
    batch_size:
        Permutations evaluated per engine tile — for the batch-fused
        statistics (Mantel family, ANOSIM) this is exactly the B grid
        dimension of ``kernels.permute_reduce``: each hoisted condensed
        invariant streams ONCE per tile and is reused by all B
        permutations, so bigger batches mean less traffic per
        permutation (peak memory is one (B, chunk) gather tile). ``None``
        (default) keeps each test's tuned default (32 everywhere since
        the condensed loop; the engine pads partial tiles so any K
        compiles exactly one program). ``"auto"``: solved from
        (n, budget) only — NEVER from K, so the one padded per-batch
        program keeps serving every K — as the largest batch whose
        (B, chunk) gather tile + (B, n) order block stay budget-resident
        (capped at 128, where the 3m/B amortization is within 3% of its
        asymptote); batch choice is bitwise-neutral (pinned by the
        engine's batch-size-invariance test).
    kernel:
        Backend for the batched condensed permutation reductions of the
        Mantel family and ANOSIM — ``"xla"`` (default; the ``lax.scan``
        twin of the kernel) or ``"pallas"`` (``kernels.permute_reduce``
        with explicit VMEM chunk streaming).
    mesh:
        Optional ``jax.sharding.Mesh`` for the distributed paths
        (``centering_impl="distributed"``, distributed matvec/engine).
    device:
        Optional ``jax.Device`` the Workspace pins its canonical matrix to
        (``None``: wherever jax placed it).
    metric:
        Default beta-diversity metric for feature-table sessions
        (``Workspace.from_features`` with ``metric=None``) — any
        ``repro.dist`` registry name ("braycurtis", "euclidean",
        "jaccard", "canberra", "cityblock").
    pairwise_impl:
        Backend for the ``repro.dist`` tiled distance production —
        ``"xla"`` (the ``lax.map`` row-panel fallback, the default) or
        ``"pallas"`` (the VMEM-tiled ``kernels.pairwise`` kernel).
    feature_block:
        Feature-axis chunk of the pairwise metric reduce: bounds the
        per-tile broadcast term at (rows, cols, feature_block).
        ``"auto"``: the solver only ever *shrinks* this under budget
        pressure, never grows it — feature_block is value-affecting
        (the metric accumulators merge once per feature chunk and fp
        addition is non-associative), and shrink-only keeps the default
        geometry whenever it fits, so auto stays bitwise-identical to
        the default on any problem the default could run.
    chunk:
        Condensed-stream chunk of ``kernels.permute_reduce`` (floats per
        scan step). ``None`` (default) keeps the kernel's 64k constant;
        ``"auto"``: the largest chunk that keeps the (B, chunk) gather
        tile + (S, chunk) invariant tile budget-resident. The observed
        statistic is chunk-independent (the per-permutation path never
        chunks); null draws accumulate per chunk, so a different chunk
        can move a null sum by an ulp — with the engine's fixed PRNG
        key the draws, and hence the p-values, are deterministic per
        chunk choice.
    auto:
        ``True`` turns every knob still at its default into ``"auto"``
        semantics in one stroke: block, feature_block, batch_size and
        chunk are all solved by ``repro.tune.solve_tiles`` when the
        config is resolved against admitted data (``Workspace`` does
        this on construction; ``repro.serve`` admission resolves it the
        same way when a study is uploaded, so every pooled session
        serves tuned tiles; standalone callers use ``resolve(n, d)``).
        Knobs set to explicit concrete values are honored untouched.
    tune_profile:
        Optional path of a ``repro.tune.save_profile`` JSON (a
        calibrated ``BackendBudget``); when set, auto-solving fits
        against the persisted budget instead of the static defaults.
    obs:
        Observability switchboard (``repro.obs.ObsConfig``). The default
        (``enabled=False``) is the zero-overhead contract: no session is
        created, every span/charge resolves to the shared no-op
        singletons. ``ObsConfig(enabled=True)`` makes the Workspace own
        an ``ObsSession`` — span tracer + analytic traffic ledger +
        recompile-sentinel window — readable via ``Workspace.report()``.
        ``None`` coerces to the disabled default.
    """

    matvec_impl: str = "xla"
    centering_impl: str = "fused"
    materialize: bool = False
    interpret: Optional[bool] = None
    block: Union[int, str] = 256
    batch_size: Union[int, str, None] = None
    kernel: str = "xla"
    mesh: Optional[Any] = None
    device: Optional[Any] = None
    metric: str = "braycurtis"
    pairwise_impl: str = "xla"
    feature_block: Union[int, str] = 128
    chunk: Union[int, str, None] = None
    auto: bool = False
    tune_profile: Optional[str] = None
    obs: Optional[ObsConfig] = ObsConfig()

    def __post_init__(self):
        if self.obs is None:
            object.__setattr__(self, "obs", ObsConfig())
        if not isinstance(self.obs, ObsConfig):
            raise ValueError(f"obs must be an ObsConfig (or None), "
                             f"got {self.obs!r}")
        if self.matvec_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown matvec_impl {self.matvec_impl!r}")
        if self.centering_impl not in ("ref", "fused", "distributed"):
            raise ValueError(f"unknown centering_impl "
                             f"{self.centering_impl!r}")
        if self.kernel not in ("xla", "pallas"):
            raise ValueError(f"unknown kernel {self.kernel!r}")
        if self.centering_impl == "distributed" and self.mesh is None:
            raise ValueError("centering_impl='distributed' requires a mesh")
        for knob in ("block", "feature_block"):
            v = getattr(self, knob)
            if not (v == "auto" or (isinstance(v, int) and v >= 1)):
                raise ValueError(f"{knob} must be an int >= 1 or 'auto', "
                                 f"got {v!r}")
        for knob in ("batch_size", "chunk"):
            v = getattr(self, knob)
            if not (v is None or v == "auto"
                    or (isinstance(v, int) and v >= 1)):
                raise ValueError(f"{knob} must be an int >= 1, 'auto' or "
                                 f"None, got {v!r}")
        if self.metric not in _KNOWN_METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; "
                             f"available: {list(_KNOWN_METRICS)}")
        if self.pairwise_impl not in ("xla", "pallas"):
            raise ValueError(f"unknown pairwise_impl "
                             f"{self.pairwise_impl!r}")

    def replace(self, **changes) -> "ExecConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)

    def resolve_batch_size(self, explicit: Optional[int],
                           default: int) -> int:
        """Precedence: explicit call-site arg > config > per-test
        default. An unresolved ``"auto"`` falls through to the engine,
        which solves it against the statistic's n."""
        if explicit is not None:
            return explicit
        if self.batch_size is not None:
            return self.batch_size
        return default

    @property
    def needs_resolution(self) -> bool:
        """True when some knob still carries auto semantics — i.e.
        ``resolve()`` would change this config."""
        return bool(self.auto or "auto" in (self.block, self.feature_block,
                                            self.batch_size, self.chunk))

    def resolve(self, n: int, d: Optional[int] = None
                ) -> "tuple[ExecConfig, Optional[Any]]":
        """Materialize auto knobs against a concrete problem size.

        Returns ``(resolved_config, tuned)`` — ``tuned`` is the
        ``repro.tune.TunedTiles`` record (chosen tiles + modeled bytes
        + the budget they were fit against) or ``None`` when nothing
        asked for tuning. ``Workspace`` calls this at admission;
        standalone users can call it directly. The import is lazy so
        this module keeps its no-repro-imports contract for every
        config that never opts in.
        """
        if not self.needs_resolution:
            return self, None
        from repro.tune.solve import resolve_exec_config
        return resolve_exec_config(self, n, d)
