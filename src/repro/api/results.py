"""Unified result dataclasses for the `repro.api` surface.

Every analysis returns one of two shapes:

* ``OrdinationResult``       — coordinates/eigenvalues/proportions of an
                               ordination (PCoA), with the solver method
                               and the RNG key that drove it recorded.
* ``PermutationTestResult``  — statistic/p-value of a Monte-Carlo
                               permutation test (defined in
                               ``repro.stats.engine``, the module that
                               owns the loop; re-exported by
                               ``repro.api``), likewise carrying the test
                               name and the resolved RNG key.

Recording the key closes the reproducibility loop: a result object alone
is enough to re-run the exact analysis (`key` + `permutations`/`method`
fully determine the Monte-Carlo draw or the fsvd range-finder).

This module deliberately imports nothing from ``repro`` so any layer can
import it without cycles (``core.pcoa`` constructs ``OrdinationResult``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax


@dataclasses.dataclass(frozen=True)
class OrdinationResult:
    """What every ordination entry point returns.

    ``coordinates`` are scaled by sqrt(lambda) (scikit-bio convention),
    ``method`` names the solver ("fsvd" | "eigh"), and ``key`` records the
    RNG key the randomized solver consumed (``None`` for the deterministic
    eigh path) so the result is self-describing and replayable.
    """

    coordinates: jax.Array           # (n, k) — samples in ordination space
    eigenvalues: jax.Array           # (k,)
    proportion_explained: jax.Array  # (k,)
    method: str = "fsvd"
    key: Optional[jax.Array] = dataclasses.field(default=None, compare=False)
