"""End-to-end driver at paper scale (the paper's kind is data analytics,
so THIS is the end-to-end example — EMP-style sample-similarity study):

    stream a large distance matrix in tiles (never fully resident)
      → validate (fused single pass)
      → PCoA (fused centering + distributed-ready fsvd)
      → Mantel test against a second metric (fused permutation engine)

    PYTHONPATH=src python examples/microbiome_pipeline.py [--n 8192]

At --n 8192 (fits this container) the pipeline mirrors the paper's 25k
runs; on a pod, core.centering/mantel switch to their shard_map paths
with the same API (see examples/distributed_analytics.py).
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistanceMatrix, mantel, pcoa
from repro.core.centering import (center_distance_matrix,
                                  center_distance_matrix_ref)
from repro.data.distance import DistanceTileStream


def main(n: int = 8192, permutations: int = 199):
    print(f"== microbiome pipeline: {n} samples (streamed in "
          f"{4096}-tiles) ==")

    # -- 1. stream the distance matrix (simulating UniFrac output) ------
    t0 = time.perf_counter()
    ds = DistanceTileStream(n=n, tile=4096, seed=0, dim=10)
    data = ds.dense()
    jax.block_until_ready(data)
    print(f"[1] streamed {n}x{n} fp32 "
          f"({data.nbytes / 1e9:.2f} GB) in {time.perf_counter() - t0:.2f}s")

    # -- 2. validation (paper §4.3) --------------------------------------
    t0 = time.perf_counter()
    dm = DistanceMatrix(data)
    print(f"[2] validated (fused single pass) in "
          f"{time.perf_counter() - t0:.2f}s")

    # -- 3. PCoA (paper §4.1) --------------------------------------------
    t0 = time.perf_counter()
    f = center_distance_matrix(dm.data)
    jax.block_until_ready(f)
    t_fused = time.perf_counter() - t0
    t0 = time.perf_counter()
    f_ref = center_distance_matrix_ref(dm.data)
    jax.block_until_ready(f_ref)
    t_ref = time.perf_counter() - t0
    print(f"[3] centering: fused {t_fused:.2f}s vs original {t_ref:.2f}s "
          f"→ {t_ref / t_fused:.1f}x (paper Table 1 effect)")
    t0 = time.perf_counter()
    res = pcoa(dm, dimensions=10, method="fsvd")
    jax.block_until_ready(res.coordinates)
    print(f"    pcoa(fsvd): {time.perf_counter() - t0:.2f}s — top "
          f"eigenvalues {np.asarray(res.eigenvalues[:3]).round(1)}")

    # -- 4. Mantel vs a second metric (paper §4.2) -----------------------
    key = jax.random.PRNGKey(1)
    noise = 0.02 * jnp.abs(jax.random.normal(key, (n, n)))
    noise = jnp.triu(noise, 1)
    dm2 = DistanceMatrix(dm.data + noise + noise.T,
                         _skip_validation=True)
    t0 = time.perf_counter()
    stat, p, _ = mantel(dm, dm2, permutations=permutations)
    print(f"[4] mantel (K={permutations}): "
          f"{time.perf_counter() - t0:.2f}s — r={stat:.4f} p={p:.4f}")
    print("== pipeline complete ==")
    return {"eigenvalues": np.asarray(res.eigenvalues),
            "mantel": (stat, p)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--permutations", type=int, default=199)
    a = ap.parse_args()
    main(a.n, a.permutations)
