"""Quickstart: the paper's two workloads through the public API.

    PYTHONPATH=src python examples/quickstart.py

Builds a valid distance matrix, runs PCoA (fused centering + randomized
eigensolver) and a Mantel test, and shows the paper's validation-caching
behaviour.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistanceMatrix, mantel, pcoa, random_distance_matrix


def main(fast: bool = False):
    n = 256 if fast else 2048
    k_perm = 49 if fast else 199
    key = jax.random.PRNGKey(0)

    print(f"== quickstart: {n} samples ==")
    dm = random_distance_matrix(key, n, dim=6)           # validated on build

    # --- PCoA (paper §4.1): fused centering + Halko fsvd ---------------
    t0 = time.perf_counter()
    res = pcoa(dm, dimensions=4, method="fsvd")
    jax.block_until_ready(res.coordinates)
    print(f"pcoa: {time.perf_counter() - t0:.3f}s — eigenvalues "
          f"{np.asarray(res.eigenvalues).round(2)} "
          f"(explained {np.asarray(res.proportion_explained).sum():.2f})")

    # --- Mantel (paper §4.2): hoisted + fused permutation test ---------
    noise = 0.05 * jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                             (n, n)))
    noise = jnp.triu(noise, 1)
    dm2 = DistanceMatrix(dm.data + noise + noise.T)
    t0 = time.perf_counter()
    stat, p, _ = mantel(dm, dm2, permutations=k_perm)
    print(f"mantel: {time.perf_counter() - t0:.3f}s — r={stat:.4f} "
          f"p={p:.4f} (K={k_perm})")

    # --- validation caching (paper §4.3) --------------------------------
    t0 = time.perf_counter()
    DistanceMatrix(dm.data)                              # full re-validation
    t_reval = time.perf_counter() - t0
    t0 = time.perf_counter()
    dm.copy()                                            # cached: free
    t_copy = time.perf_counter() - t0
    print(f"validation: revalidate {t_reval * 1e3:.1f}ms vs copy "
          f"{t_copy * 1e3:.3f}ms (paper §4.3 caching)")

    return {"pcoa_dims": int(res.coordinates.shape[1]),
            "mantel_stat": float(stat), "mantel_p": float(p)}


if __name__ == "__main__":
    main()
