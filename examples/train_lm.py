"""LM-substrate end-to-end driver: train a llama-family model on the
structured synthetic corpus with checkpointing + straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py                # demo (~10M)
    PYTHONPATH=src python examples/train_lm.py --preset 100m  # full driver

The demo preset fits this CPU container; the 100m preset is the "train a
~100M model for a few hundred steps" driver sized for real hardware
(same code path — only the config literal changes).
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.configs import SMOKES
import repro.configs  # noqa: F401  (registers archs)
from repro.launch import train as train_launch

PRESETS = {
    # ~10M params: demonstrably learns the synthetic grammar on CPU
    "demo": dict(
        cfg=ModelConfig(
            name="demo-25m", family="dense", n_layers=4, d_model=256,
            n_heads=8, n_kv_heads=4, head_dim=32, d_ff=1024, vocab=4096,
            pattern=("attn",), param_dtype="float32",
            compute_dtype="float32", tie_embeddings=True),
        steps=80, batch=8, seq=128, lr=1e-3),
    # ~100M params, few hundred steps: the full end-to-end driver
    "100m": dict(
        cfg=ModelConfig(
            name="driver-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab=32768,
            pattern=("attn",), tie_embeddings=True),
        steps=300, batch=32, seq=512, lr=6e-4),
}


def main(preset: str = "demo", ckpt_dir: str = "/tmp/repro_train_lm"):
    p = PRESETS[preset]
    cfg = p["cfg"]
    print(f"== train_lm [{preset}]: {cfg.param_count() / 1e6:.1f}M params, "
          f"{p['steps']} steps ==")

    # register so the launcher can find it
    from repro.configs.base import ARCHS, SMOKES as SM
    ARCHS[cfg.name] = cfg
    SM[cfg.name] = cfg

    args = train_launch.build_argparser().parse_args([
        "--arch", cfg.name, "--steps", str(p["steps"]),
        "--batch", str(p["batch"]), "--seq", str(p["seq"]),
        "--lr", str(p["lr"]), "--ckpt-dir", ckpt_dir,
        "--ckpt-every", "50", "--log-every", "10"])
    res = train_launch.run(args)
    first, last = res["losses"][0], res["losses"][-1]
    print(f"== loss {first:.3f} → {last:.3f} over {p['steps']} steps ==")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=sorted(PRESETS))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    a = ap.parse_args()
    main(a.preset, a.ckpt_dir)
