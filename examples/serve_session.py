"""Multi-tenant serving walkthrough — three studies, one front door.

The library story so far is single-tenant: one ``Workspace``, one
analyst, hoists amortized within a session. ``repro.serve`` is the same
economics made multi-tenant — an ``AnalysisService`` pools sessions in
a bounded LRU, coalesces concurrent permutation requests against the
same study into shared padded tiles (continuous batching, with
permutation tiles where an LLM server has token slots), and streams
partial p-values with a deterministic confidence envelope while the
tiles drain.

This example plays three labs sharing one service instance:

* three studies uploaded (two feature-backed, one from a precomputed
  square distance matrix) — each pays its O(n²) hoists exactly once, at
  upload;
* nine concurrent requests across the full battery (pcoa, permanova,
  anosim, permdisp, mantel, partial_mantel) at mixed per-request K —
  same-study mantel requests share tiles, so the scheduler runs
  ceil(ΣK/B) tiles, not Σceil(K/B);
* an async client that awaits its own handle while the shared
  ``arun()`` driver turns tiles for everyone, printing streamed
  ``StreamUpdate`` frames as its confidence interval tightens;
* a structured rejection (a NaN upload bounces with a payload, not a
  traceback) and the final ``serve_report()`` — pool residency, tile
  counts, per-study ledgers, latency quantiles.

    PYTHONPATH=src python examples/serve_session.py [--n 256]
"""

import argparse
import asyncio
import json

import numpy as np

from repro.serve import AnalysisService, Rejected, ServeConfig, serve_report


def make_studies(n: int, seed: int = 7):
    rng = np.random.default_rng(seed)
    gradient = np.linspace(0.0, 1.0, n)[:, None]
    gut = (rng.random((n, 16)) + 0.8 * gradient).astype(np.float32)
    soil = (rng.random((n, 12)) + 0.5 * gradient).astype(np.float32)
    climate = (rng.random((n, 4)) + gradient).astype(np.float32)
    grouping = np.asarray(["ctl", "low", "mid", "high"])[
        rng.integers(0, 4, size=n)]
    return gut, soil, climate, grouping


async def main(n: int) -> None:
    gut, soil, climate, grouping = make_studies(n)
    svc = AnalysisService(ServeConfig(batch_size=32, max_sessions=8,
                                      timeout_s=120.0))

    # -- uploads: the hoist bill is paid here, once per study ------------
    for sid, feats in (("gut", gut), ("soil", soil)):
        ack = svc.upload(sid, features=feats)
        print(f"[upload] {sid:<8} n={ack['n']} backing={ack['backing']} "
              f"hoist bytes={ack['cache_nbytes']}")
    # the climate study arrives as a precomputed square matrix
    from repro.api.workspace import Workspace
    climate_dm = np.asarray(Workspace.from_features(climate).dm.data)
    ack = svc.upload("climate", climate_dm)
    print(f"[upload] climate  n={ack['n']} backing={ack['backing']}")

    # -- a bad upload is a payload, not a traceback ----------------------
    poisoned = gut.copy()
    poisoned[3, 2] = np.nan
    try:
        svc.upload("oops", features=poisoned)
    except Rejected as e:
        print(f"[reject] {json.dumps(e.rejection.payload())}")

    # -- nine concurrent requests, mixed methods and K -------------------
    handles = [
        svc.submit("gut", "permanova", grouping=grouping,
                   permutations=999, key=0),
        svc.submit("gut", "permdisp", grouping=grouping,
                   permutations=499, key=1),
        svc.submit("gut", "anosim", grouping=grouping,
                   permutations=249, key=2),
        # three same-lane mantel requests: these COALESCE into shared
        # tiles (one hoist_lane, ceil((999+499+99)/32)=50 tiles)
        svc.submit("gut", "mantel", other="soil", permutations=999, key=3),
        svc.submit("gut", "mantel", other="soil", permutations=499, key=4),
        svc.submit("gut", "mantel", other="soil", permutations=99, key=5),
        svc.submit("gut", "partial_mantel", other="soil",
                   control="climate", permutations=499, key=6),
        svc.submit("soil", "permanova", grouping=grouping,
                   permutations=999, key=7),
        svc.submit("gut", "pcoa", dimensions=3),
    ]
    watched = handles[3]          # the K=999 mantel: stream its frames

    async def watch(handle):
        """A client awaiting its own result, reporting the stream."""
        seen = 0

        def flush():
            nonlocal seen
            for u in handle.updates[seen:]:
                if u.draws_done % 256 < 32 or u.done:
                    print(f"[stream] {handle.method} "
                          f"{u.draws_done:>4}/{u.permutations} draws  "
                          f"p ∈ [{u.p_lo:.4f}, {u.p_hi:.4f}]"
                          + ("  <- final" if u.done else ""))
            seen = len(handle.updates)

        while not handle.done:
            flush()
            await asyncio.sleep(0)
        flush()
        return handle

    done, _ = await asyncio.gather(watch(watched), svc.arun())
    if done.result is not None:
        print(f"[stream] final p={done.result.p_value:.4f} — inside every "
              f"streamed interval by construction")

    # -- results: payload() is one uniform shape for EVERY terminal
    # state (done / degraded / rejected / timed_out), so the loop
    # branches on status, never on which fields happen to exist --------
    print("\nrequest            status    result")
    for h in handles:
        p = h.payload()
        if p["error"] is not None:
            desc = f"{p['error']['code']}: {p['error']['message'][:40]}"
            if p["progress"] is not None:       # degraded: envelope
                desc += (f"  p ∈ [{p['progress']['p_lo']:.4f}, "
                         f"{p['progress']['p_hi']:.4f}]")
        elif h.method == "pcoa":
            desc = f"coords {h.result.coordinates.shape}"
        else:
            r = p["result"]
            desc = (f"stat={r['statistic']:+.4f} "
                    f"p={r['p_value']:.4f} (K={h.permutations})")
        print(f"{h.request_id:>4} {h.method:<14}{h.status:<8}  {desc}")

    # -- the service-wide report -----------------------------------------
    rep = serve_report(svc)
    g = rep["gauges"]
    print(f"\n[report] {g['completed']} completed | "
          f"{rep['scheduler']['tiles_run']} tiles of "
          f"B={rep['scheduler']['batch_size']} | "
          f"median latency {g['latency_s']['median'] * 1e3:.0f}ms | "
          f"{rep['pool']['sessions']} pooled sessions, "
          f"{rep['pool']['nbytes']} hoist bytes resident")
    for sid, s in rep["studies"].items():
        print(f"[report]   {sid:<8} hoists built "
              f"{sum(s['hoist_builds'].values())}x "
              f"(hit {sum(s['hoist_hits'].values())}x), "
              f"{s['cache_nbytes']} bytes")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    asyncio.run(main(ap.parse_args().n))
