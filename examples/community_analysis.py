"""Community ecology walkthrough on the repro.stats engine.

The paper's motivating workload (§1) is microbiome beta-diversity: compute
distance matrices, then ask statistical questions of them. This example
runs the full battery on one simulated study — the personal-device-scale
analysis of Sfiligoi et al. 2021:

    samples from 4 "treatment" groups, two metrics + one confounder
      → PCoA        where do the samples sit?    (matrix-free ordination)
      → PERMANOVA   do group centroids differ?        (pseudo-F)
      → PERMDISP    ...or is it just unequal spread?  (dispersion F)
      → ANOSIM      do within < between distances?    (Clarke's R)
      → Mantel      do the two metrics agree?         (Pearson r)
      → partial Mantel   ...controlling for the confounding gradient?

PCoA runs matrix-free through ``core.operators.CenteredGramOperator`` —
the n×n Gower matrix is never materialized, which is what lets the
large-cohort sizes fit on a personal device — and PERMDISP reuses those
same coordinates as its hoisted invariant (a significant PERMANOVA with a
significant PERMDISP warns that location and dispersion are confounded).

    PYTHONPATH=src python examples/community_analysis.py [--n 2048]

Every test shares one hoisted+fused Monte-Carlo engine
(repro.stats.engine): permutation-invariant work — Gower centering,
ranks, ŷ/ẑ normalization + residualization — happens once; each of the
K permutations is a single fused pass. Compare any test against its
eager ``*_ref`` oracle via ``benchmarks/run.py --suite stats``.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistanceMatrix, mantel, pcoa
from repro.stats import anosim, partial_mantel, permanova, permdisp


def _euclidean_dm(pts):
    d2 = jnp.sum((pts[:, None] - pts[None, :]) ** 2, -1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    d = 0.5 * (d + d.T)
    return DistanceMatrix(d - jnp.diag(jnp.diag(d)), _skip_validation=True)


def simulate_study(key, n, num_groups=4, dim=8):
    """Two community metrics + a confounding environmental gradient.

    Sample i sits at (group centroid) + (gradient effect) + noise; metric B
    is metric A re-measured with noise, and the gradient alone drives the
    confounder matrix — so partial Mantel should keep A~B strong while a
    naive Mantel of A vs the gradient matrix is spurious.
    """
    k_grp, k_grad, k_a, k_b = jax.random.split(key, 4)
    grouping = np.arange(n) % num_groups
    centroids = 2.0 * jax.random.normal(k_grp, (num_groups, dim))
    gradient = jax.random.normal(k_grad, (n, 1))           # e.g. pH
    base = (centroids[grouping]
            + 1.5 * gradient * jnp.ones((1, dim))
            + jax.random.normal(k_a, (n, dim)))
    metric_a = _euclidean_dm(base)
    metric_b = _euclidean_dm(base + 0.3 * jax.random.normal(k_b, (n, dim)))
    confounder = _euclidean_dm(gradient)
    return grouping, metric_a, metric_b, confounder


def main(n: int = 2048, permutations: int = 999):
    key = jax.random.PRNGKey(0)
    grouping, metric_a, metric_b, confounder = simulate_study(key, n)
    test_key = jax.random.PRNGKey(1)
    print(f"== community analysis: {n} samples, 4 groups, K={permutations} ==")

    t0 = time.perf_counter()
    ord_ = pcoa(metric_a, dimensions=3)          # matrix-free by default
    jax.block_until_ready(ord_.coordinates)
    pe = np.asarray(ord_.proportion_explained)
    print(f"[0] PCoA (matrix-free)  top-3 axes explain "
          f"{100 * pe.sum():.1f}% of inertia "
          f"({time.perf_counter() - t0:.2f}s, no n² intermediate)")

    t0 = time.perf_counter()
    r = permanova(metric_a, grouping, permutations, test_key)
    print(f"[1] PERMANOVA      F={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s)")

    t0 = time.perf_counter()
    r = permdisp(metric_a, grouping, permutations, test_key, dimensions=10)
    print(f"[2] PERMDISP       F={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — location vs spread check")

    t0 = time.perf_counter()
    r = anosim(metric_a, grouping, permutations, test_key)
    print(f"[3] ANOSIM         R={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s)")

    t0 = time.perf_counter()
    s, p, _ = mantel(metric_a, metric_b, permutations, test_key)
    print(f"[4] Mantel A~B     r={s:8.3f}  p={p:.4f}  "
          f"({time.perf_counter() - t0:.2f}s)")

    t0 = time.perf_counter()
    s, p, _ = mantel(metric_a, confounder, permutations, test_key)
    print(f"[5] Mantel A~env   r={s:8.3f}  p={p:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — the confounded read")

    t0 = time.perf_counter()
    r = partial_mantel(metric_a, metric_b, confounder, permutations, test_key)
    print(f"[6] partial A~B|env r={r.statistic:7.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — agreement survives the "
          f"control")
    print("== analysis complete ==")
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--permutations", type=int, default=999)
    a = ap.parse_args()
    main(a.n, a.permutations)
