"""Community ecology walkthrough — one hoist-once Workspace session,
opened straight from the raw feature table.

The paper's motivating workload (§1) is microbiome beta-diversity:
compute distance matrices, then ask statistical questions of them. This
example runs the full battery on one simulated study — the
personal-device-scale analysis of Sfiligoi et al. 2021:

    samples from 4 "treatment" groups, two measurements + one confounder
      → PCoA        where do the samples sit?    (matrix-free ordination)
      → PERMANOVA   do group centroids differ?        (pseudo-F)
      → PERMDISP    ...or is it just unequal spread?  (dispersion F)
      → ANOSIM      do within < between distances?    (Clarke's R)
      → Mantel      do the two measurements agree?    (Pearson r)
      → partial Mantel   ...controlling for the confounding gradient?

Everything runs through ``repro.api.Workspace`` — and since the
``repro.dist`` subsystem, the session starts one step earlier than a
distance matrix: ``Workspace.from_features`` turns the (n, d) sample
table into CONDENSED distances tile-by-tile, accumulating the operator
means during the same sweep — and since the condensed batch-fused
permutation loop (``kernels.permute_reduce``), ALL SEVEN analyses below
complete without an n×n matrix of any kind ever existing: the Mantel
family and ANOSIM gather condensed storage by closed-form triangle
indexing (~11x less per-permutation traffic than the old square-gather
loop — the audited analytic accounting is ``BENCH_mantel.json``, via
``benchmarks/run.py --suite mantel``). The shared hoists are computed on
first use and reused by every later test; one ``ExecConfig`` carries
every execution knob; every result records its RNG key.

The primary session runs with **observability on**
(``ExecConfig(obs=ObsConfig(enabled=True))``): every analysis and hoist
is a timed span, every build/batch is charged to the analytic traffic
ledger, and the run ends by printing the span tree and the ledger
totals — the same ``RunReport`` document CI archives from ``--smoke``.

    PYTHONPATH=src python examples/community_analysis.py [--n 2048]

Legacy style (still supported — each call is a thin wrapper over a
one-shot Workspace, identical p-values per key, but the hoists are NOT
shared across calls, and you build the square matrix yourself):

    from scipy.spatial.distance import pdist, squareform   # or repro.dist
    from repro.core import DistanceMatrix, mantel, pcoa
    from repro.stats import anosim, partial_mantel, permanova, permdisp
    metric_a = DistanceMatrix(squareform(pdist(table)))
    ord_ = pcoa(metric_a, dimensions=3)
    r = permanova(metric_a, grouping, 999, key)      # re-centers
    r = permdisp(metric_a, grouping, 999, key)       # re-ordinates
    r = anosim(metric_a, grouping, 999, key)         # re-ranks
    s, p, _ = mantel(metric_a, metric_b, 999, key)   # re-normalizes
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExecConfig, Workspace
from repro.obs import ObsConfig


def simulate_study(key, n, num_groups=4, dim=8):
    """Two community measurements + a confounding environmental gradient.

    Sample i sits at (group centroid) + (gradient effect) + noise; table B
    is table A re-measured with noise, and the gradient alone drives the
    confounder — so partial Mantel should keep A~B strong while a naive
    Mantel of A vs the gradient is spurious. Returned as raw (n, d)
    feature tables: building the distances is part of the workload now.
    """
    k_grp, k_grad, k_a, k_b = jax.random.split(key, 4)
    grouping = np.arange(n) % num_groups
    centroids = 2.0 * jax.random.normal(k_grp, (num_groups, dim))
    gradient = jax.random.normal(k_grad, (n, 1))           # e.g. pH
    table_a = (centroids[grouping]
               + 1.5 * gradient * jnp.ones((1, dim))
               + jax.random.normal(k_a, (n, dim)))
    table_b = table_a + 0.3 * jax.random.normal(k_b, (n, dim))
    return grouping, table_a, table_b, gradient


def main(n: int = 2048, permutations: int = 999):
    key = jax.random.PRNGKey(0)
    grouping, table_a, table_b, gradient = simulate_study(key, n)
    test_key = 1                     # int seeds and PRNG keys both accepted
    print(f"== community analysis: {n} samples, 4 groups, K={permutations} ==")

    # one session per measurement: the feature table is validated finite +
    # canonicalized once, distances are produced condensed with the
    # operator means fused into the sweep. ExecConfig is where execution
    # knobs go (metric=..., pairwise_impl="pallas", matvec_impl="pallas",
    # a mesh for the distributed paths, ...) — defaults suit one CPU/TPU.
    # obs=ObsConfig(enabled=True) turns the primary session's telemetry
    # on: spans + analytic traffic ledger (off by default: zero overhead).
    ws = Workspace.from_features(table_a, metric="euclidean",
                                 config=ExecConfig(
                                     obs=ObsConfig(enabled=True)))
    ws_b = Workspace.from_features(table_b, metric="euclidean")
    ws_env = Workspace.from_features(gradient, metric="euclidean")

    t0 = time.perf_counter()
    ord_ = ws.pcoa(dimensions=3)                 # matrix-free by default
    jax.block_until_ready(ord_.coordinates)
    pe = np.asarray(ord_.proportion_explained)
    print(f"[0] PCoA (matrix-free)  top-3 axes explain "
          f"{100 * pe.sum():.1f}% of inertia "
          f"({time.perf_counter() - t0:.2f}s, no n² intermediate)")

    t0 = time.perf_counter()
    r = ws.permanova(grouping, permutations, test_key)
    print(f"[1] PERMANOVA      F={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s)")

    t0 = time.perf_counter()
    r = ws.permdisp(grouping, permutations, test_key, dimensions=3)
    print(f"[2] PERMDISP       F={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — reused [0]'s ordination")

    t0 = time.perf_counter()
    r = ws.anosim(grouping, permutations, test_key)
    print(f"[3] ANOSIM         R={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s)")

    assert "square" not in ws.cache
    print(f"    -- four analyses done, no n×n matrix of any kind ever "
          f"existed (even ANOSIM's ranks stayed condensed; cached: "
          f"{sorted(k if isinstance(k, str) else k[0] for k in ws.cache.keys())})")

    t0 = time.perf_counter()
    r = ws.mantel(ws_b, permutations, test_key)
    print(f"[4] Mantel A~B     r={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — condensed batch loop, "
          f"square built: {'square' in ws.cache}")

    t0 = time.perf_counter()
    r = ws.mantel(ws_env, permutations, test_key)
    print(f"[5] Mantel A~env   r={r.statistic:8.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — the confounded read")

    t0 = time.perf_counter()
    r = ws.partial_mantel(ws_b, ws_env, permutations, test_key)
    print(f"[6] partial A~B|env r={r.statistic:7.3f}  p={r.p_value:.4f}  "
          f"({time.perf_counter() - t0:.2f}s) — agreement survives the "
          f"control")

    # the whole seven-analysis study ran square-free, in every session
    for w in (ws, ws_b, ws_env):
        assert "square" not in w.cache and w._dm is None

    families = {k if isinstance(k, str) else k[0] for k in ws.cache.misses}
    builds = {a: ws.cache.build_count(a) for a in sorted(families)}
    print(f"== analysis complete — hoists built once each: {builds}, "
          f"cache hits: {sum(ws.cache.hits.values())} ==")

    # -- the observability readout: where the time and the bytes went ----
    # (the same data ws.report() serializes as a RunReport JSON document)
    print("\n== span tree (primary session; wall seconds) ==")
    for line in ws.obs.tracer.tree_lines():
        print("  " + line)
    report = ws.report(meta={"example": "community_analysis"})
    led = report.ledger
    print(f"== analytic traffic ledger: {led['hoist_passes']:.1f} n²-pass "
          f"equivalents of hoist traffic, {led['total_bytes'] / 1e6:.1f} MB "
          f"total analytic ==")
    for op, v in sorted(led["by_op"].items()):
        print(f"   {op:22s} {v['bytes'] / 1e6:10.2f} MB  x{v['count']}")
    print(f"== recompile window: "
          f"{ {k: v['programs'] for k, v in report.compile.items()} } "
          f"(one kernels.permute_reduce program per invariant-stack "
          f"shape, whatever K) ==")

    # -- measured vs modeled: the compiled programs' actual byte counts
    # (obs.probe, ahead-of-time compile, scan-corrected) reconciled
    # against the analytic envelope (obs.drift)
    if report.measured:
        print("\n== measured (AOT probes, scan-corrected bytes) ==")
        for name, rec in sorted(report.measured.items()):
            print(f"   {name:26s} {rec['bytes_corrected'] / 1e6:10.2f} MB "
                  f"moved, peak {rec['peak_bytes'] / 1e6:8.2f} MB")
        print(f"== drift verdicts (measured inside the modeled "
              f"envelope?) ==")
        for v in report.drift["verdicts"]:
            print(f"   {v['name']:26s} {v['quantity']:5s} "
                  f"{v['measured'] / 1e6:10.2f} MB in "
                  f"[{v['expected_lo'] / 1e6:.2f}, "
                  f"{v['expected_hi'] / 1e6:.2f}] "
                  f"{'OK' if v['within'] else 'DRIFT'}  ({v['regime']})")
        verdict = ("within tolerance" if report.drift_ok
                   else "DRIFT DETECTED")
        print(f"== drift: {verdict} on backend "
              f"{report.drift['backend']} ==")
    return r


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--permutations", type=int, default=999)
    a = ap.parse_args()
    main(a.n, a.permutations)
