"""Pod-scale analytics demo: the paper's algorithms under shard_map.

Runs on 8 emulated devices (this file sets the device-count flag FIRST,
so run it as a script, not an import):

    PYTHONPATH=src python examples/distributed_analytics.py

Shows the DESIGN §2 claim: block-local two-pass centering with one O(n)
psum per pass, and permutation-parallel Mantel — only O(n) bytes and
per-permutation scalars cross the interconnect.
"""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main(n: int = 4096, permutations: int = 64):
    from repro.core import random_distance_matrix
    from repro.core.centering import (center_distance_matrix,
                                      center_distance_matrix_distributed)
    from repro.core.mantel import mantel, mantel_distributed

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    print(f"== distributed analytics on mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} ==")

    dm = random_distance_matrix(jax.random.PRNGKey(0), n).data
    t0 = time.perf_counter()
    f_dist = center_distance_matrix_distributed(dm, mesh)
    jax.block_until_ready(f_dist)
    t_dist = time.perf_counter() - t0
    f_local = center_distance_matrix(dm)
    err = float(np.abs(np.asarray(f_dist) - np.asarray(f_local)).max())
    print(f"centering: distributed {t_dist:.2f}s, max|Δ| vs fused = "
          f"{err:.2e}")

    x = random_distance_matrix(jax.random.PRNGKey(1), n // 4)
    y = random_distance_matrix(jax.random.PRNGKey(2), n // 4)
    key = jax.random.PRNGKey(9)
    t0 = time.perf_counter()
    s_d, p_d, _ = mantel_distributed(x, y, mesh, permutations=permutations,
                                     key=key)
    t_dist = time.perf_counter() - t0
    s_l, p_l, _ = mantel(x, y, permutations=permutations, key=key)
    print(f"mantel: distributed {t_dist:.2f}s — r={s_d:.4f} (local "
          f"{s_l:.4f}), p={p_d:.3f} (local {p_l:.3f})")
    print("== done ==")


if __name__ == "__main__":
    main()
